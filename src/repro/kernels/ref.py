"""Pure-jnp oracles for every Pallas kernel in this package.

These are the single source of truth for kernel semantics; every kernel test
sweeps shapes/dtypes and asserts allclose against these functions, and the
model code uses them as the non-TPU fallback path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def flash_attention_ref(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: float = 0.0,
) -> Array:
    """Grouped-query attention oracle.

    q: (B, H, S, D); k, v: (B, KV, T, D) with H % KV == 0.
    Sliding window: query at position i attends keys in (i-window, i].
    Returns (B, H, S, D) in q.dtype.
    """
    b, h, s, d = q.shape
    _, kv, t, _ = k.shape
    g = h // kv
    qg = q.reshape(b, kv, g, s, d).astype(jnp.float32)
    logits = jnp.einsum("bkgsd,bktd->bkgst", qg, k.astype(jnp.float32))
    logits = logits / jnp.sqrt(jnp.float32(d))
    if logit_softcap:
        logits = jnp.tanh(logits / logit_softcap) * logit_softcap
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)


def rwkv6_scan_ref(
    r: Array, k: Array, v: Array, w: Array, u: Array, s0: Array
) -> Tuple[Array, Array]:
    """WKV-6 recurrence oracle.

    r,k,v,w: (B, H, T, D); u: (H, D); s0: (B, H, D, D) [key x value dims].
        y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns y (B, H, T, D) fp32, S_T (B, H, D, D) fp32.
    """
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf, sf = u.astype(jnp.float32), s0.astype(jnp.float32)

    def step(s, xs):
        r_t, k_t, v_t, w_t = xs  # (B, H, D)
        kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)
        y = jnp.einsum("bhi,bhij->bhj", r_t, s + uf[None, :, :, None] * kv)
        return w_t[..., None] * s + kv, y

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (rf, kf, vf, wf))
    s_fin, ys = jax.lax.scan(step, sf, xs)
    return jnp.moveaxis(ys, 0, 2), s_fin


def rglru_scan_ref(a: Array, x: Array, h0: Array) -> Tuple[Array, Array]:
    """Diagonal linear recurrence oracle: h_t = a_t * h_{t-1} + x_t.

    a, x: (B, T, W); h0: (B, W). Returns (h (B,T,W) fp32, h_T (B,W) fp32).
    """
    af, xf, hf = (z.astype(jnp.float32) for z in (a, x, h0))

    def step(h, xs):
        a_t, x_t = xs
        h = a_t * h + x_t
        return h, h

    h_fin, hs = jax.lax.scan(
        step, hf, (jnp.moveaxis(af, 1, 0), jnp.moveaxis(xf, 1, 0))
    )
    return jnp.moveaxis(hs, 0, 1), h_fin
