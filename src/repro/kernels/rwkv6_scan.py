"""RWKV-6 WKV recurrence kernel for TPU (Pallas): chunked linear scan.

TPU adaptation of the CUDA wkv kernel: instead of one thread per channel
with registers, we block time into chunks and keep the per-(batch, head)
state matrix S (D_k x D_v) resident in VMEM scratch across the sequential
innermost grid dimension (TPU grids execute minor-to-major, so scratch
carries state between time chunks of the same (b, h) without HBM round
trips). Within a chunk the recurrence is a fori_loop of rank-1 updates —
outer products hit the VPU/MXU at (D x D) granularity.

    y_t = r_t^T (S + diag(u) k_t v_t^T);   S <- diag(w_t) S + k_t v_t^T

Layouts: r/k/v/w (B, H, T, D); u (H, D); s0 (B, H, D, D).
Grid (B, H, T / Ct), chunk index innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
    y_ref, sfin_ref,
    s_scratch,
    *,
    chunk: int,
    n_chunks: int,
):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _load_state():
        s_scratch[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)  # (D,)
    r = r_ref[0, 0].astype(jnp.float32)  # (Ct, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)

    def step(t, ys):
        s = s_scratch[...]  # (Dk, Dv)
        r_t = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)  # (1, D)
        k_t = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        v_t = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        w_t = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        kv = k_t.T @ v_t  # (Dk, Dv) rank-1
        y_t = (r_t * u[None, :]) @ kv + r_t @ s  # (1, Dv)
        s_scratch[...] = w_t.T * s + kv
        return jax.lax.dynamic_update_slice_in_dim(ys, y_t, t, 0)

    ys = jax.lax.fori_loop(
        0, chunk, step, jnp.zeros((chunk, r.shape[1]), jnp.float32)
    )
    y_ref[0, 0] = ys.astype(y_ref.dtype)

    @pl.when(ti == n_chunks - 1)
    def _store_state():
        sfin_ref[0, 0] = s_scratch[...].astype(sfin_ref.dtype)


def _largest_divisor(n: int, preferred: int) -> int:
    b = min(n, preferred)
    while n % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    s0: jax.Array,
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """r,k,v,w: (B, H, T, D); u: (H, D); s0: (B, H, D, D).

    Returns y (B, H, T, D) fp32 and final state (B, H, D, D) fp32.
    """
    b, h, t, d = r.shape
    ct = _largest_divisor(t, chunk)
    n_chunks = t // ct
    kernel = functools.partial(_wkv_kernel, chunk=ct, n_chunks=n_chunks)
    y, sfin = pl.pallas_call(
        kernel,
        grid=(b, h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, ct, d), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, ct, d), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, ct, d), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, ct, d), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, d), lambda bi, hi, ti: (hi, 0)),
            pl.BlockSpec((1, 1, d, d), lambda bi, hi, ti: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, ct, d), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, d, d), lambda bi, hi, ti: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sfin
