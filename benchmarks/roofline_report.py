"""Roofline rows from dry-run artifacts (run repro.launch.dryrun first)."""
from __future__ import annotations

from benchmarks.common import Claims, row
from repro.launch import roofline


def run(claims: Claims):
    rows = []
    n_ok = 0
    for mesh in ("single", "multi"):
        for rec in roofline.load_all(mesh):
            r = roofline.derive(rec)
            if r is None:
                continue
            n_ok += 1
            rows.append(
                row(
                    f"roofline/{mesh}/{r.arch}/{r.shape}",
                    r.step_time_s * 1e6,
                    f"bound={r.bottleneck} compute={r.compute_s*1e3:.2f}ms "
                    f"mem={r.memory_s*1e3:.2f}ms coll={r.collective_s*1e3:.2f}ms "
                    f"useful={r.useful_ratio:.2f} frac={r.roofline_fraction:.2f}",
                )
            )
    if n_ok:
        claims.check(
            "Dry-run: roofline terms derived for every compiled cell",
            True,
            f"{n_ok} cells",
        )
    else:
        claims.check(
            "Dry-run: roofline terms derived for every compiled cell",
            False,
            "no artifacts found — run `python -m repro.launch.dryrun --all`",
        )
    return rows
