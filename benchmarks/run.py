"""Benchmark harness entry point: one module per paper table/figure plus the
TPU-adaptation benches. Prints ``name,us_per_call,derived`` CSV rows and a
paper-claim validation summary.

    PYTHONPATH=src python -m benchmarks.run [--only fig1]
    PYTHONPATH=src python -m benchmarks.run --only eval_matrix \
        --bench-json BENCH_eval_matrix.json

``--bench-json`` writes the eval-matrix perf trajectory (scenarios/sec per
backend, wall times, grid size, jax/numpy crossover) so future PRs have a
baseline to beat; the checked-in ``BENCH_eval_matrix.json`` is the first
point of that trajectory.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks.common import Claims

MODULES = [
    ("fig1_2", "benchmarks.fig1_fig2_param_sweeps"),
    ("fig5_6", "benchmarks.fig5_fig6_chunk_counts"),
    ("fig7", "benchmarks.fig7_dataset_size"),
    ("fig9_11", "benchmarks.fig9_10_11_datasets"),
    ("fig12_13", "benchmarks.fig12_fig13_promc_lan"),
    ("eval_matrix", "benchmarks.eval_matrix_bench"),
    ("grad_sync", "benchmarks.grad_sync_bench"),
    ("checkpoint", "benchmarks.checkpoint_bench"),
    ("kernels", "benchmarks.kernel_bench"),
    ("roofline", "benchmarks.roofline_report"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on module")
    ap.add_argument(
        "--bench-json", default=None, metavar="PATH",
        help="write the eval_matrix perf snapshot to PATH "
        "(runs the eval_matrix bench if --only filtered it out)",
    )
    args = ap.parse_args()

    # arm the persistent XLA compilation cache up front when opted in
    # (REPRO_XLA_CACHE): the jax benches then measure cache reads, not
    # recompiles, and a fresh CI runner inherits prior runs' programs
    from repro.eval.fabric.xla_cache import enable_persistent_cache

    enable_persistent_cache()

    claims = Claims()
    print("name,us_per_call,derived")
    t_start = time.time()
    for key, modname in MODULES:
        if args.only and args.only not in key:
            if not (args.bench_json and key == "eval_matrix"):
                continue
        t0 = time.time()
        mod = __import__(modname, fromlist=["run"])
        try:
            rows = mod.run(claims)
        except Exception as e:  # a failed bench is reported, not fatal
            print(f"{key}/ERROR,0,{type(e).__name__}: {e}", flush=True)
            claims.check(f"bench {key} runs", False, str(e)[:200])
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}",
                  flush=True)
        print(f"# {key} done in {time.time()-t0:.1f}s", file=sys.stderr)

    if args.bench_json:
        from benchmarks import eval_matrix_bench

        if eval_matrix_bench.LAST_SNAPSHOT is None:
            print("# bench-json: eval_matrix did not produce a snapshot",
                  file=sys.stderr)
        else:
            with open(args.bench_json, "w") as f:
                json.dump(eval_matrix_bench.LAST_SNAPSHOT, f, indent=1)
                f.write("\n")
            print(f"# wrote {args.bench_json}", file=sys.stderr)

    print(claims.report())
    print(f"# total {time.time()-t_start:.1f}s", file=sys.stderr)
    n_missed = sum(not r["ok"] for r in claims.results)
    if n_missed:
        print(f"# WARNING: {n_missed} claim(s) missed", file=sys.stderr)


if __name__ == "__main__":
    main()
