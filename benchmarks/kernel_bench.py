"""Pallas kernel microbenchmarks (interpret-mode on CPU: correctness-scale
timings; real perf comes from the dry-run roofline) plus ref-path timings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Claims, row, timed
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan

KEY = jax.random.PRNGKey(0)


def run(claims: Claims):
    rows = []

    # flash attention: kernel (interpret) vs jnp oracle
    b, h, kv, s, d = 1, 4, 2, 512, 64
    q = jax.random.normal(KEY, (b, h, s, d), jnp.float32)
    k = jax.random.normal(KEY, (b, kv, s, d), jnp.float32)
    v = jax.random.normal(KEY, (b, kv, s, d), jnp.float32)
    fa = jax.jit(
        lambda q, k, v: flash_attention(
            q, k, v, causal=True, window=128, block_q=128, block_k=128,
            interpret=True,
        )
    )
    _ = fa(q, k, v)  # compile
    _, us = timed(lambda: jax.block_until_ready(fa(q, k, v)))
    rows.append(row("kernel/flash_attention_interp_512", us, f"S={s} w=128"))
    fr = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True, window=128))
    _ = fr(q, k, v)
    _, us_ref = timed(lambda: jax.block_until_ready(fr(q, k, v)))
    rows.append(row("kernel/flash_attention_ref_512", us_ref, "jnp oracle"))

    # rwkv6 chunked scan
    bb, hh, t, dd = 1, 4, 256, 64
    r_ = jax.random.normal(KEY, (bb, hh, t, dd)) * 0.5
    w_ = jnp.exp(-jnp.exp(jax.random.normal(KEY, (bb, hh, t, dd)) * 0.5))
    u_ = jax.random.normal(KEY, (hh, dd)) * 0.5
    s0 = jnp.zeros((bb, hh, dd, dd))
    wk = jax.jit(lambda: rwkv6_scan(r_, r_, r_, w_, u_, s0, chunk=64,
                                    interpret=True))
    _ = wk()
    _, us = timed(lambda: jax.block_until_ready(wk()))
    rows.append(row("kernel/rwkv6_scan_interp_256", us, f"T={t} D={dd}"))
    wr = jax.jit(lambda: ref.rwkv6_scan_ref(r_, r_, r_, w_, u_, s0))
    _ = wr()
    _, us_ref = timed(lambda: jax.block_until_ready(wr()))
    rows.append(row("kernel/rwkv6_scan_ref_256", us_ref, "lax.scan oracle"))

    # rg-lru scan
    a_ = jax.nn.sigmoid(jax.random.normal(KEY, (2, 512, 256)))
    x_ = jax.random.normal(KEY, (2, 512, 256)) * 0.5
    h0 = jnp.zeros((2, 256))
    rg = jax.jit(lambda: rglru_scan(a_, x_, h0, chunk=128, block_w=128,
                                    interpret=True))
    _ = rg()
    _, us = timed(lambda: jax.block_until_ready(rg()))
    rows.append(row("kernel/rglru_scan_interp_512", us, "T=512 W=256"))

    claims.check(
        "Kernels: all three Pallas kernels execute in interpret mode",
        True,
        "flash_attention, rwkv6_scan, rglru_scan",
    )
    return rows
