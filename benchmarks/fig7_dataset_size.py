"""Figure 7: dataset-size sweep for partitioning techniques (MC, maxCC=6)."""
from __future__ import annotations

from benchmarks.common import Claims, row
from repro.core import run_transfer, testbeds, to_gbps
from repro.core.types import GB
from repro.data.filesets import equal_class_dataset


def run(claims: Claims):
    rows = []
    results = {}
    for total_gb in (4, 16, 64, 128):
        files = equal_class_dataset(total_gb * GB, seed=total_gb)
        for nc in (1, 2, 3, 4):
            r = run_transfer(
                files, testbeds.STAMPEDE_COMET, "mc", max_cc=6, num_chunks=nc
            )
            results[(total_gb, nc)] = r.throughput
            rows.append(
                row(
                    f"fig7/{total_gb}GB/{nc}chunk",
                    r.total_time * 1e6,
                    f"{to_gbps(r.throughput):.2f}Gbps",
                )
            )

    # --- claims (Sec. 4.1 / Fig. 7) ---
    worst_one = min(
        results[(g, 1)] / max(results[(g, n)] for n in (2, 3, 4))
        for g in (16, 64, 128)
    )
    claims.check(
        "Fig7: 1-chunk underperforms partitioned transfers on larger datasets",
        worst_one < 1.0,
        f"1-chunk/best ratio (worst case): {worst_one:.3f}",
    )
    big = 128
    claims.check(
        "Fig7: 2-chunk >= 4-chunk as dataset size grows",
        results[(big, 2)] >= results[(big, 4)] * 0.97,
        f"128GB: 2-chunk {to_gbps(results[(big,2)]):.2f} vs 4-chunk "
        f"{to_gbps(results[(big,4)]):.2f} Gbps",
    )
    return rows
