"""Figures 5 & 6: impact of chunk count (1-4) in WAN and LAN, per algorithm."""
from __future__ import annotations

from benchmarks.common import Claims, row
from repro.core import run_transfer, testbeds, to_gbps
from repro.data.filesets import chunk_count_mixed


def run(claims: Claims):
    rows = []
    files = chunk_count_mixed(scale=0.08)
    results = {}
    for net_name, net, ccs in (
        ("wan", testbeds.STAMPEDE_COMET, (2, 4, 8, 16)),
        ("lan", testbeds.LAN, (2, 4, 8)),
    ):
        for algo in ("sc", "mc", "promc"):
            for nc in (1, 2, 3, 4):
                series = []
                for cc in ccs:
                    r = run_transfer(files, net, algo, max_cc=cc, num_chunks=nc)
                    series.append(r.throughput)
                    rows.append(
                        row(
                            f"fig5_6/{net_name}/{algo}/{nc}chunk/maxcc={cc}",
                            r.total_time * 1e6,
                            f"{to_gbps(r.throughput):.2f}Gbps",
                        )
                    )
                results[(net_name, algo, nc)] = series

    # --- claims (Sec. 4.1) ---
    mc2 = results[("wan", "mc", 2)]
    claims.check(
        "Fig5: MC reaches ~9 Gbps on the 10G WAN at maxCC>=8",
        to_gbps(max(mc2)) > 8.0,
        f"MC 2-chunk peak {to_gbps(max(mc2)):.2f} Gbps",
    )
    sc2 = results[("wan", "sc", 2)]
    claims.check(
        "Fig5: SC plateaus after concurrency 4 (self-limiting heuristic)",
        sc2[-1] / sc2[1] < 1.1,
        f"SC maxCC 4->16: {sc2[-1]/sc2[1]:.3f}x",
    )
    one = results[("wan", "mc", 1)]
    multi = results[("wan", "mc", 2)]
    claims.check(
        "Fig5: 1-chunk up to ~20% worse than 2-chunk at small maxCC (MC)",
        multi[0] >= one[0] * 0.99,
        f"maxCC=2: 1-chunk {to_gbps(one[0]):.2f} vs 2-chunk {to_gbps(multi[0]):.2f} Gbps",
    )
    c2, c3, c4 = (results[("wan", "mc", n)] for n in (2, 3, 4))
    spread = max(max(c2), max(c3), max(c4)) / min(max(c2), max(c3), max(c4))
    claims.check(
        "Fig5: >2 chunks adds little (2/3/4-chunk within ~10%)",
        spread < 1.1,
        f"peak spread {spread:.3f}x",
    )
    lan = results[("lan", "mc", 2)]
    claims.check(
        "Fig6: LAN throughput dips when maxCC exceeds the 4-server backend",
        lan[-1] <= lan[1] * 1.02,
        f"LAN MC maxCC 4->8: {lan[-1]/lan[1]:.3f}x",
    )
    return rows
