"""Real-I/O checkpoint benchmark: the TransferEngine (threads, striping,
scheduled channels) writing an actual train state to local disk, SC vs MC
scheduling vs a plain sequential writer."""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import Claims, row
from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.models.config import reduce_for_smoke
from repro.models.model import build_model
from repro.train.train_step import init_train_state


def _sequential_save(state, directory, step):
    """Baseline: plain loop, one file at a time, no engine."""
    import io, json

    os.makedirs(directory, exist_ok=True)
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    leaves = ckpt._flatten(state)
    index = {"step": step, "leaves": {}}
    for name, arr in leaves:
        fname = name.replace("/", "_") + ".npy"
        np.save(os.path.join(d, fname), arr, allow_pickle=False)
        index["leaves"][name] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)
        }
    with open(os.path.join(d, "index.json"), "w") as f:
        json.dump(index, f)


def run(claims: Claims):
    rows = []
    # a mid-size state: a few hundred MB so timings are meaningful but quick
    import dataclasses

    cfg = dataclasses.replace(
        reduce_for_smoke(get_config("llama3.2-3b")),
        d_model=512, d_ff=2048, num_layers=8, vocab_size=32768,
        num_heads=8, num_kv_heads=8, head_dim=64,
    )
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    n_bytes = sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree.leaves(state)
    )

    results = {}
    base = tempfile.mkdtemp(prefix="ckpt_bench_")
    try:
        t0 = time.perf_counter()
        _sequential_save(state, os.path.join(base, "seq"), 0)
        results["sequential"] = time.perf_counter() - t0
        for algo in ("sc", "mc", "promc"):
            t0 = time.perf_counter()
            ckpt.save(state, os.path.join(base, algo), 0, algorithm=algo,
                      max_cc=4)
            results[algo] = time.perf_counter() - t0
        # restore timing
        t0 = time.perf_counter()
        loaded, _ = ckpt.restore(os.path.join(base, "mc"))
        results["restore"] = time.perf_counter() - t0
        ok_roundtrip = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(loaded))
        )
    finally:
        shutil.rmtree(base, ignore_errors=True)

    for name, t in results.items():
        rows.append(
            row(
                f"checkpoint/{name}",
                t * 1e6,
                f"{n_bytes/1e6:.0f}MB at {n_bytes/t/1e6:.0f}MB/s",
            )
        )
    claims.check(
        "Engine: checkpoint save/restore round-trips bit-exact",
        ok_roundtrip,
        f"{n_bytes/1e6:.0f} MB state",
    )
    claims.check(
        "Engine: scheduled concurrent save not slower than sequential writer",
        results["mc"] < results["sequential"] * 1.5,
        f"mc {results['mc']*1e3:.0f}ms vs sequential "
        f"{results['sequential']*1e3:.0f}ms",
    )
    return rows
