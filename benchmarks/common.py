"""Shared benchmark plumbing: row emission + claim checks."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

ROW_FIELDS = ("name", "us_per_call", "derived")


def row(name: str, us_per_call: float, derived: str) -> Dict:
    return {"name": name, "us_per_call": us_per_call, "derived": derived}


class Claims:
    """Collects paper-claim validations; reported at the end of the run."""

    def __init__(self):
        self.results: List[Dict] = []

    def check(self, claim: str, ok: bool, detail: str):
        self.results.append({"claim": claim, "ok": bool(ok), "detail": detail})

    def report(self) -> str:
        lines = ["", "# Paper-claim validation"]
        for r in self.results:
            mark = "PASS" if r["ok"] else "MISS"
            lines.append(f"[{mark}] {r['claim']} — {r['detail']}")
        n_ok = sum(r["ok"] for r in self.results)
        lines.append(f"# {n_ok}/{len(self.results)} claims validated")
        return "\n".join(lines)


def timed(fn: Callable, *args, repeat: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6  # us
