"""Figure 12: MC vs ProMC on small-file-dominated datasets.
Figure 13: LAN comparison incl. Globus Connect Personal degradation."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Claims, row
from repro.core import run_transfer, testbeds, to_gbps
from repro.core.types import GB, MB, FileSpec
from repro.data.filesets import mixed_dataset, small_dominated_mixed


def byte_dominated_small(total=40 * GB, seed=7):
    """Small files carry 60% of the bytes (the regime Fig. 12 probes)."""
    rng = np.random.RandomState(seed)
    files, budget, i = [], total * 0.6, 0
    while budget > 0:
        s = int(rng.uniform(1 * MB, 5 * MB))
        files.append(FileSpec(f"s/{i}", s))
        budget -= s
        i += 1
    files += [
        FileSpec(f"l/{j}", 500 * MB) for j in range(int(total * 0.4 / (500 * MB)))
    ]
    return files


def run(claims: Claims):
    rows = []
    # --- Fig 12 ---
    gains = []
    for name, files in (
        ("paper-doubled", small_dominated_mixed(scale=0.04)),
        ("byte-dominated", byte_dominated_small()),
    ):
        for cc in (8, 12, 16):
            rm = run_transfer(files, testbeds.STAMPEDE_COMET, "mc", max_cc=cc)
            rp = run_transfer(files, testbeds.STAMPEDE_COMET, "promc", max_cc=cc)
            gain = rp.throughput / rm.throughput - 1
            gains.append(gain)
            rows.append(
                row(
                    f"fig12/{name}/maxcc={cc}",
                    rp.total_time * 1e6,
                    f"MC={to_gbps(rm.throughput):.2f}Gbps "
                    f"ProMC={to_gbps(rp.throughput):.2f}Gbps ({gain*100:+.1f}%)",
                )
            )
    claims.check(
        "Fig12: ProMC beats MC on small-file-dominated data (paper: up to 10%)",
        max(gains) > 0.02,
        f"best ProMC gain {max(gains)*100:.1f}%",
    )

    # --- Fig 13 ---
    mx = mixed_dataset(scale=0.03)
    lan = {}
    for algo, kw in (
        ("untuned", {}),
        ("globus", {"connect_personal": True}),
        ("sc", {}),
        ("mc", {}),
        ("promc", {}),
    ):
        r = run_transfer(mx, testbeds.LAN, algo, max_cc=4, **kw)
        lan[algo] = r.throughput
        rows.append(
            row(
                f"fig13/lan/{algo}",
                r.total_time * 1e6,
                f"{to_gbps(r.throughput)*1000:.0f}Mbps",
            )
        )
    claims.check(
        "Fig13: Globus Connect Personal ~500 Mbps on LAN",
        0.2 < to_gbps(lan["globus"]) < 1.0,
        f"{to_gbps(lan['globus'])*1000:.0f} Mbps",
    )
    claims.check(
        "Fig13: our algorithms exceed 2 Gbps on LAN",
        to_gbps(lan["mc"]) > 2.0 and to_gbps(lan["promc"]) > 2.0,
        f"MC {to_gbps(lan['mc']):.2f} Gbps",
    )
    return rows
