"""DCN gradient-sync schedule quality (the paper's technique on the TPU
fabric): simulated completion time of one cross-pod sync for each assigned
architecture under naive / SC / MC / ProMC scheduling, with and without
per-class compression."""
from __future__ import annotations

import jax

from benchmarks.common import Claims, row
from repro.configs import ARCHS
from repro.distributed import grad_sync
from repro.models.model import build_model, param_shapes

BENCH_ARCHS = ("deepseek-moe-16b", "yi-9b", "gemma3-1b", "whisper-base")


def grad_shapes_for(arch: str):
    model = build_model(ARCHS[arch])
    return param_shapes(model)  # grads mirror params


def run(claims: Claims):
    rows = []
    results = {}
    for arch in BENCH_ARCHS:
        shapes = grad_shapes_for(arch)
        for name, kw in (
            # true untuned baseline: one channel, one stream, no window
            ("naive", dict(algorithm="untuned", max_cc=1, num_chunks=1,
                           compress_by_class=grad_sync.NO_COMPRESSION)),
            ("sc", dict(algorithm="sc", max_cc=8,
                        compress_by_class=grad_sync.NO_COMPRESSION)),
            ("mc", dict(algorithm="mc", max_cc=8,
                        compress_by_class=grad_sync.NO_COMPRESSION)),
            ("promc", dict(algorithm="promc", max_cc=8,
                           compress_by_class=grad_sync.NO_COMPRESSION)),
            ("promc+bf16", dict(algorithm="promc", max_cc=8)),
        ):
            r = grad_sync.simulate_sync(shapes, **kw)
            results[(arch, name)] = r.total_time
            rows.append(
                row(
                    f"grad_sync/{arch}/{name}",
                    r.total_time * 1e6,
                    f"{r.total_bytes/1e9:.2f}GB in {r.total_time*1e3:.1f}ms "
                    f"({r.throughput/1e9:.1f}GB/s)",
                )
            )

    speedups = [
        results[(a, "naive")] / results[(a, "promc")] for a in BENCH_ARCHS
    ]
    claims.check(
        "Adaptation: paper-scheduled DCN sync beats untuned single-channel sync",
        min(speedups) > 1.3,
        f"speedups {['%.1fx' % s for s in speedups]}",
    )
    # compression only applies where bandwidth-bound (Medium+) chunks exist;
    # gemma3-1b / whisper-base grads are all Small-class at DCN thresholds.
    comp_archs = ("deepseek-moe-16b", "yi-9b")
    comp = [
        results[(a, "promc")] / results[(a, "promc+bf16")] for a in comp_archs
    ]
    claims.check(
        "Beyond-paper: per-class bf16 compression accelerates sync on "
        "bandwidth-bound gradient classes",
        min(comp) > 1.2,
        f"extra speedups {['%.1fx' % s for s in comp]} on {comp_archs}",
    )
    return rows
