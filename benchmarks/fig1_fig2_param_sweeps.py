"""Figures 1 & 2: individual parameter effects on XSEDE and LONI.

Sweeps pipelining / parallelism / concurrency one at a time over five file
sizes (1 MB .. 10 GB), reproducing the paper's observations:
pipelining helps small files (up to ~2x), parallelism helps large files on
buffer-limited paths, concurrency helps everything.
"""
from __future__ import annotations

from benchmarks.common import Claims, row
from repro.core import testbeds
from repro.core.baselines import _StaticOneChunkScheduler
from repro.core.chunking import partition_files
from repro.core.simulator import Simulation
from repro.core.types import GB, MB, TransferParams, to_gbps
from repro.data.filesets import uniform_files
from repro.eval import run_simulations

FILE_SIZES = {
    "1MB": (1 * MB, 400),
    "10MB": (10 * MB, 120),
    "100MB": (100 * MB, 40),
    "1GB": (1 * GB, 16),
    "10GB": (10 * GB, 8),
}

SWEEPS = {
    "pipelining": [0, 1, 2, 4, 8, 16],
    "parallelism": [1, 2, 4, 8],
    "concurrency": [1, 2, 4, 8],
}


def fixed_sim(net, files, pp, p, cc):
    chunks = partition_files(files, net, 1)
    sched = _StaticOneChunkScheduler(
        chunks, net, cc, TransferParams(pipelining=pp, parallelism=p, concurrency=cc)
    )
    return Simulation(sched.chunks, net, sched, tick_period=5.0)


def run(claims: Claims):
    rows = []
    # one batch sweep over the whole (network x size x parameter) grid via
    # the eval matrix runner's vectorized fast path
    grid = []
    sims = []
    for net_name, net in (("xsede", testbeds.XSEDE), ("loni", testbeds.LONI)):
        for size_name, (size, n) in FILE_SIZES.items():
            files = uniform_files(n, size)
            for param, values in SWEEPS.items():
                for v in values:
                    pp, p, cc = 0, 1, 1
                    if param == "pipelining":
                        pp = v
                    elif param == "parallelism":
                        p = v
                    else:
                        cc = v
                    sims.append(fixed_sim(net, files, pp, p, cc))
                    grid.append((net_name, size_name, param, v))
    sweep = run_simulations(
        sims, names=[f"{n}/{s}/{p}={v}" for n, s, p, v in grid]
    )

    results = {}
    for (net_name, size_name, param, v), r in zip(grid, sweep):
        results.setdefault((net_name, size_name, param), []).append(
            r.throughput
        )
        rows.append(
            row(
                f"fig1_2/{net_name}/{size_name}/{param}={v}",
                r.total_time * 1e6,
                f"{to_gbps(r.throughput):.3f}Gbps",
            )
        )

    # --- claims (Sec. 3 / Figs 1-2) ---
    x1 = results[("xsede", "1MB", "pipelining")]
    claims.check(
        "Fig1a: pipelining improves small-file throughput up to ~2x",
        1.5 <= x1[-1] / x1[0] <= 2.4,
        f"1MB XSEDE: {x1[-1]/x1[0]:.2f}x at pp=16",
    )
    xh = results[("xsede", "10GB", "pipelining")]
    claims.check(
        "Fig1a: pipelining negligible for large files",
        xh[-1] / xh[0] < 1.05,
        f"10GB XSEDE: {xh[-1]/xh[0]:.3f}x",
    )
    ph = results[("xsede", "10GB", "parallelism")]
    claims.check(
        "Fig1b: parallelism helps large files (buffer < BDP)",
        ph[-1] / ph[0] > 1.3,
        f"10GB XSEDE: {ph[-1]/ph[0]:.2f}x at p=8",
    )
    ps = results[("xsede", "1MB", "parallelism")]
    claims.check(
        "Fig1b: parallelism does not help small files",
        ps[-1] / ps[0] < 1.05,
        f"1MB XSEDE: {ps[-1]/ps[0]:.3f}x",
    )
    pl = results[("loni", "10GB", "parallelism")]
    claims.check(
        "Fig2b: parallelism unneeded when buffer >= BDP (LONI)",
        pl[-1] / pl[0] < 1.1,
        f"10GB LONI: {pl[-1]/pl[0]:.3f}x",
    )
    for size_name in ("1MB", "10GB"):
        c = results[("xsede", size_name, "concurrency")]
        claims.check(
            f"Fig1c: concurrency broadly effective ({size_name})",
            c[-1] / c[0] > 3.0,
            f"XSEDE {size_name}: {c[-1]/c[0]:.1f}x at cc=8",
        )
    return rows
