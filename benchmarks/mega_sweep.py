"""Standalone 16k+-row oracle-plane sweep with clean per-run telemetry.

The mega-sweep leg of ``BENCH_eval_matrix.json`` and CI's peak-RSS /
multi-device gates run this as a *subprocess* for two reasons the
in-process bench cannot work around:

  * ``ru_maxrss`` is a process-lifetime high-water mark, so an
    in-process measurement inherits whatever the earlier full-grid legs
    peaked at — a fresh process measures the sweep itself;
  * the XLA host device count is fixed at jax import
    (``--xla_force_host_platform_device_count``), so a 4-simulated-device
    scaling row needs its own interpreter.

Prints one JSON object on stdout (last line). ``--assert-rss-mb`` and
``--assert-min-rows-per-s`` turn it into a regression gate: non-zero
exit when the sweep's peak RSS exceeds the bound or its throughput
falls below it.

Usage::

    PYTHONPATH=src:. python -m benchmarks.mega_sweep \
        --devices 4 --candidates 64 --json
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--devices", type=int, default=0,
        help="simulate N host devices (0 = leave jax alone); must be "
        "applied before jax imports, which is why this is its own "
        "process",
    )
    ap.add_argument("--candidates", type=int, default=64)
    ap.add_argument("--backend", default="jax")
    ap.add_argument("--matrix", default="full",
                    choices=("smoke", "default", "full", "tenant",
                             "tenant-smoke"))
    ap.add_argument(
        "--executor", default=None, choices=("serial", "async"),
        help="chunk executor mode (default: REPRO_FABRIC_EXECUTOR/async)",
    )
    ap.add_argument(
        "--assert-rss-mb", type=float, default=None,
        help="fail (exit 1) if the sweep's peak RSS exceeds this bound",
    )
    ap.add_argument(
        "--assert-min-rows-per-s", type=float, default=None,
        help="fail (exit 1) if the sweep's throughput falls below this "
        "bound — CI runs the 4-device sweep against the measured "
        "1-device rate so multi-device scaling can't silently regress",
    )
    args = ap.parse_args(argv)

    if args.devices:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + flags
        )
    # everything jax-adjacent imports after the flag is set
    import jax

    from repro.eval.fabric import executor as fabric_executor
    from repro.eval.fabric import jax_backend
    from repro.eval.runner import build_matrix
    from repro.eval.tune import oracle_search

    scenarios = build_matrix(args.matrix)
    extra = {}
    if args.matrix.startswith("tenant"):
        # the fleet leg: coupled throughput (steady, warm cache) vs the
        # same rows with the fabric stripped — the coupled-path overhead
        # — plus the contention report (greedy per-tenant heuristics vs
        # the contended static oracle, scored on the NumPy ground truth)
        import dataclasses as _dc

        from repro.eval.runner import run_matrix
        from repro.eval.tune.contention import contention_report

        run_matrix(scenarios, backend=args.backend,
                   executor=args.executor)  # warm compile/caches
        t0 = time.perf_counter()
        run_matrix(scenarios, backend=args.backend, executor=args.executor)
        wall = time.perf_counter() - t0
        stripped = [
            _dc.replace(sc, shared_fabric=None) for sc in scenarios
        ]
        run_matrix(stripped, backend=args.backend, executor=args.executor)
        t0 = time.perf_counter()
        run_matrix(stripped, backend=args.backend, executor=args.executor)
        uncoupled_wall = time.perf_counter() - t0
        evals = len(scenarios)
        extra = {
            "uncoupled_wall_s": round(uncoupled_wall, 3),
            "coupled_overhead": round(
                wall / max(uncoupled_wall, 1e-9), 3
            ),
            "contention": contention_report(
                scenarios, backend="numpy",
                n_candidates=min(args.candidates, 8),
            ).summary(),
        }
    else:
        t0 = time.perf_counter()
        result = oracle_search(
            scenarios,
            backend=args.backend,
            n_candidates=args.candidates,
            executor=args.executor,
        )
        wall = time.perf_counter() - t0
        evals = result.evals
    peak_rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    row = {
        "evals": evals,
        "wall_s": round(wall, 3),
        "rows_per_s": round(evals / max(wall, 1e-9), 1),
        "peak_rss_mb": round(peak_rss, 1),
        "backend": args.backend,
        "matrix": args.matrix,
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "device_count": jax.device_count(),
        "executor": fabric_executor.executor_mode(args.executor),
        "donation": jax_backend.donation_enabled(),
        "compiled_programs": jax_backend.compiled_program_count(),
        **extra,
    }
    print(json.dumps(row))
    if args.assert_rss_mb is not None and peak_rss > args.assert_rss_mb:
        print(
            f"FAIL: peak RSS {peak_rss:.0f} MB exceeds the "
            f"{args.assert_rss_mb:.0f} MB gate",
            file=sys.stderr,
        )
        return 1
    if (
        args.assert_min_rows_per_s is not None
        and row["rows_per_s"] < args.assert_min_rows_per_s
    ):
        print(
            f"FAIL: {row['rows_per_s']:.1f} rows/s below the "
            f"{args.assert_min_rows_per_s:.1f} rows/s gate",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
