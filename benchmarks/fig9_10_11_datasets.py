"""Figures 9-11: algorithm comparison on DES / genome / mixed datasets across
the three XSEDE site pairs, vs Globus Online and the untuned baseline.

The whole 135-point sweep runs as ONE batch through the eval matrix runner
(the vectorized fast path, exact-equivalent to the event simulator per
eval.difftest), so adding points to the grid barely moves the wall clock."""
from __future__ import annotations

from benchmarks.common import Claims, row
from repro.core import testbeds, to_gbps
from repro.core.runner import build_scheduler
from repro.core.simulator import Simulation
from repro.data.filesets import (
    dark_energy_survey,
    genome_sequencing,
    mixed_dataset,
)
from repro.eval import run_simulations

PAIRS = {
    "bw-stampede": testbeds.BLUEWATERS_STAMPEDE,
    "stampede-comet": testbeds.STAMPEDE_COMET,
    "supermic-bridges": testbeds.SUPERMIC_BRIDGES,
}

DATASETS = {
    "des": lambda: dark_energy_survey(scale=0.15),
    "genome": lambda: genome_sequencing(scale=0.015),
    "mixed": lambda: mixed_dataset(scale=0.04),
}

ALGOS = ("untuned", "globus", "sc", "mc", "promc")


def run(claims: Claims):
    rows = []
    # assemble the full grid, then execute it as one batch sweep
    grid = []
    sims = []
    for ds_name, make in DATASETS.items():
        files = make()
        for pair, net in PAIRS.items():
            for algo in ALGOS:
                for cc in (4, 8, 16):
                    sched = build_scheduler(algo, files, net, max_cc=cc)
                    sims.append(
                        Simulation(sched.chunks, sched.network, sched)
                    )
                    grid.append((ds_name, pair, algo, cc))
    sweep = run_simulations(
        sims, names=[f"{d}/{p}/{a}/cc{c}" for d, p, a, c in grid]
    )

    results = {}
    for (ds_name, pair, algo, cc), r in zip(grid, sweep):
        results[(ds_name, pair, algo)] = max(
            results.get((ds_name, pair, algo), 0.0), r.throughput
        )
        rows.append(
            row(
                f"fig9_11/{ds_name}/{pair}/{algo}/maxcc={cc}",
                r.total_time * 1e6,
                f"{to_gbps(r.throughput):.2f}Gbps",
            )
        )

    # --- claims (Sec. 4.2) ---
    des_bw = {a: results[("des", "bw-stampede", a)] for a in ALGOS}
    claims.check(
        "Fig9a: MC/ProMC ~22 Gbps on BlueWaters-Stampede DES",
        to_gbps(des_bw["mc"]) > 18 and to_gbps(des_bw["promc"]) > 18,
        f"MC {to_gbps(des_bw['mc']):.1f} / ProMC {to_gbps(des_bw['promc']):.1f} Gbps",
    )
    claims.check(
        "Fig9a: Globus Online stays <= ~8.5 Gbps on DES",
        to_gbps(des_bw["globus"]) < 9.5,
        f"Globus {to_gbps(des_bw['globus']):.1f} Gbps",
    )
    claims.check(
        "Fig9a: SC worst of the tuned algorithms on DES",
        des_bw["sc"] < des_bw["mc"] and des_bw["sc"] < des_bw["promc"],
        f"SC {to_gbps(des_bw['sc']):.1f} Gbps",
    )
    claims.check(
        "Fig9c: SuperMIC-Bridges reaches ~4 Gbps at high concurrency "
        "(4MB-buffer path)",
        3.0 < to_gbps(results[("des", "supermic-bridges", "mc")]) < 6.0,
        f"MC {to_gbps(results[('des','supermic-bridges','mc')]):.1f} Gbps",
    )
    gen = {a: results[("genome", "stampede-comet", a)] for a in ALGOS}
    claims.check(
        "Fig10: MC/ProMC land in the paper's 1.5-3.5 Gbps band on genome",
        1.2 < to_gbps(gen["mc"]) < 4.5,
        f"MC {to_gbps(gen['mc']):.2f} Gbps",
    )
    claims.check(
        "Fig10: SC competitive on genome (small-file dominated)",
        gen["sc"] / gen["mc"] > 0.6,
        f"SC/MC = {gen['sc']/gen['mc']:.2f}",
    )
    claims.check(
        "Abstract: up to ~10x over the untuned baseline",
        gen["mc"] / gen["untuned"] > 8,
        f"genome MC/untuned = {gen['mc']/gen['untuned']:.1f}x",
    )
    claims.check(
        "Abstract: large gain vs state of the art (Globus) on small files",
        gen["mc"] / gen["globus"] > 2,
        f"genome MC/Globus = {gen['mc']/gen['globus']:.1f}x",
    )
    mx = {a: results[("mixed", "stampede-comet", a)] for a in ALGOS}
    claims.check(
        "Fig11: MC/ProMC significantly better than Globus on mixed",
        mx["mc"] > mx["globus"] * 1.2,
        f"MC {to_gbps(mx['mc']):.1f} vs Globus {to_gbps(mx['globus']):.1f} Gbps",
    )
    return rows
