"""Scenario-matrix throughput across backends: the perf trajectory bench.

Measures scenarios/sec of the event reference, the NumPy fabric driver,
and the JAX jit/vmap driver on the acceptance grid (``full_matrix``, 1000+
scenarios; ``BENCH_EVAL_GRID=smoke`` shrinks it for CI), plus the
jax/numpy ratio at increasing grid sizes so the crossover point — the
grid size beyond which the device loop beats eager NumPy — is part of the
record. ``benchmarks/run.py --bench-json`` serializes :data:`LAST_SNAPSHOT`
to ``BENCH_eval_matrix.json`` so future PRs have a baseline to beat.

JAX wall time is recorded cold (first run, including XLA compilation) and
steady (second run, compile cache warm); scenarios/sec uses the steady
number, which is what matters for sweep workloads that run grids
repeatedly.
"""
from __future__ import annotations

import json
import os
import resource
import subprocess
import sys
import time
from typing import Dict, List, Optional

from benchmarks.common import row
from repro.eval import run_matrix
from repro.eval.fabric import executor as _fabric_executor
from repro.eval.fabric import jax_backend as _jax_backend
from repro.eval.fabric import xla_cache
from repro.eval.scenarios import default_matrix, full_matrix, smoke_matrix

#: repo root (the subprocess legs run ``python -m benchmarks.mega_sweep``
#: from here so ``src`` + the benchmarks package resolve)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: snapshot of the last run(), serialized by ``run.py --bench-json``
LAST_SNAPSHOT: Optional[Dict] = None

_JAX_TARGET_RATIO = 2.0

#: cold-compile budget: first-run wall may exceed steady by at most this
#: many seconds on the full grid (canonical bucketing keeps the trace
#: count flat; the persistent XLA cache turns recompiles into disk reads)
_COLD_BUDGET_S = 20.0


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _provenance() -> Dict:
    """Execution provenance for cross-snapshot comparability: two
    snapshots' ratios only mean something when they ran the same
    executor/donation/device configuration — the event-canary drift
    note covers machine speed, this covers execution mode."""
    import jax

    return {
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "device_count": jax.device_count(),
        "executor": _fabric_executor.executor_mode(),
        "donation": _jax_backend.donation_enabled(),
        "cpu_count": os.cpu_count(),
    }


def _mega_subprocess(
    n_candidates: int, devices: int = 0, timeout: float = 1800.0,
    matrix: Optional[str] = None,
) -> Optional[Dict]:
    """One ``benchmarks.mega_sweep`` run in a fresh interpreter: clean
    per-run peak RSS (``ru_maxrss`` is process-lifetime, so in-process
    numbers inherit earlier legs' peaks) and, for ``devices > 0``, a
    simulated multi-device topology (the XLA host device count is fixed
    at jax import). Returns the parsed JSON row, or None on failure
    (recorded as an absent leg, never a bench crash)."""
    cmd = [
        sys.executable, "-m", "benchmarks.mega_sweep",
        "--candidates", str(n_candidates),
    ]
    if devices:
        cmd += ["--devices", str(devices)]
    if matrix:
        cmd += ["--matrix", matrix]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (
            os.path.join(_ROOT, "src"), _ROOT,
            env.get("PYTHONPATH", ""),
        ) if p
    )
    try:
        proc = subprocess.run(
            cmd, cwd=_ROOT, env=env, capture_output=True, text=True,
            timeout=timeout,
        )
        return json.loads(proc.stdout.strip().splitlines()[-1])
    except Exception:
        return None


def _time_backend(scenarios, backend: str, repeat: int = 2):
    """Time one backend over the grid; returns ``(metrics, results)`` —
    the last run's results ride along so consumers (the tuner leg's
    regret report) don't pay a redundant full sweep."""
    t0 = time.perf_counter()
    results = run_matrix(scenarios, backend=backend)
    cold = time.perf_counter() - t0
    # steady state: best of ``repeat`` further runs (for jax the first run
    # above also populated the XLA compile cache)
    steady = cold if backend != "jax" else float("inf")
    if backend == "jax":
        _jax_backend.reset_sync_stats()
    for _ in range(repeat if backend == "jax" else repeat - 1):
        t0 = time.perf_counter()
        results = run_matrix(scenarios, backend=backend)
        steady = min(steady, time.perf_counter() - t0)
    out = {
        "wall_s_cold": round(cold, 3),
        "wall_s": round(steady, 3),
        "scen_per_s": round(len(scenarios) / max(steady, 1e-9), 2),
    }
    if backend == "jax":
        # host-sync telemetry of the fused loop: device rounds are
        # while_loop entries shared by the whole batch (compaction /
        # straggler re-entries included); host rounds are the rounds that
        # ended in a Python replay of parked rows, and post_row_replays
        # the parked rows themselves — all zero for built-in schedulers
        # since the sweep went zero-host-round
        stats = dict(_jax_backend.SYNC_STATS)
        runs = max(stats.pop("runs"), 1)
        scen = max(stats["scenarios"] // runs, 1)
        out["device_rounds_per_scenario"] = round(
            stats["rounds"] / runs / scen, 4
        )
        out["host_rounds_per_scenario"] = round(
            stats["replay_rounds"] / runs / scen, 4
        )
        out["post_row_replays_per_run"] = stats["post_row_replays"] // runs
    return out, results


#: candidate budget of the bench's tuner leg per grid (the full grid
#: carries the acceptance-bar budget; smoke keeps CI fast)
_TUNE_CANDIDATES = {"smoke": 16, "default": 32, "full": 64}


def _time_tuner(scenarios, grid_name: str, claims, heuristics) -> Dict:
    """Oracle-regret + successive-halving leg of the snapshot.

    The regret oracle runs over the *bench grid* on the NumPy driver
    (ground truth, no compile variance in the timing). On the full grid
    the same 16k+-row candidate plane is then swept a second time on
    jax as the **mega-sweep leg**: canonical bucketing pre-expands the
    plane into a handful of compiled shapes, so the sweep's wall clock
    and peak RSS — not its compile count — are what the snapshot
    records. The successive-halving budget bar is always measured on
    the smoke matrix (its acceptance definition) against a smoke
    oracle.
    """
    from repro.eval.tune import (
        oracle_search,
        regret_report,
        successive_halving,
    )

    n_candidates = _TUNE_CANDIDATES[grid_name]
    backend = "numpy"
    t0 = time.perf_counter()
    oracle = oracle_search(
        scenarios, backend=backend, n_candidates=n_candidates
    )
    oracle_wall = time.perf_counter() - t0
    report = regret_report(scenarios, heuristics, oracle)

    # the SHA bar is defined at 64 candidates on the smoke matrix, which
    # never matches the regret leg's grid/budget — its oracle is its own
    smoke = smoke_matrix()
    smoke_oracle = oracle_search(smoke, backend=backend, n_candidates=64)
    t0 = time.perf_counter()
    sha = successive_halving(smoke, backend=backend, n_candidates=64)
    sha_wall = time.perf_counter() - t0
    by_ctx = {e.context: e.best_throughput for e in smoke_oracle.entries}
    sha_worst = min(
        e.best_throughput / max(by_ctx[e.context], 1e-12)
        for e in sha.entries
    )
    # the mega-sweep leg: the full candidate plane (>= 10k rows) on the
    # jax driver through the pipelined executor, run in a *fresh
    # subprocess* so peak RSS is the sweep's own (not inherited from the
    # grid legs above), plus a 4-simulated-device scaling row from a
    # second subprocess (the XLA host device count is import-time)
    mega = None
    if grid_name == "full":
        mega = _mega_subprocess(n_candidates)
        if mega is not None:
            scaling = _mega_subprocess(n_candidates, devices=4)
            if scaling is not None:
                mega["scaling_4dev"] = {
                    k: scaling[k]
                    for k in (
                        "wall_s", "rows_per_s", "peak_rss_mb",
                        "device_count", "executor", "donation",
                    )
                }
            jax_wall = mega["wall_s"]
            rss_peak = mega["peak_rss_mb"]
            claims.check(
                "16k+-row candidate plane sweeps on jax via columnar "
                "plan ingest: peak RSS <= 1.6 GB and wall >= 1.5x "
                "faster than the NumPy oracle (warm cache)",
                mega["evals"] >= 10_000
                and rss_peak <= 1638.0
                and jax_wall * 1.5 <= oracle_wall,
                f"{mega['evals']} rows in {jax_wall:.1f}s "
                f"(numpy {oracle_wall:.1f}s), peak RSS {rss_peak:.0f} MB, "
                f"{mega['compiled_programs']} compiled programs, "
                f"executor={mega['executor']} donation={mega['donation']}",
            )
            if scaling is not None:
                # multi-core hosts must scale positive across devices;
                # on a single core the executor caps the virtual-device
                # fanout, so the row degenerates to the 1-device path
                # and only gross collapse (the pre-cap 0.44x) is wrong
                cores = os.cpu_count() or 1
                floor = 1.0 if cores >= 2 else 0.9
                claims.check(
                    "4-simulated-device sweep holds the 1-device rate "
                    "(>= 1.0x on multi-core hosts; >= 0.9x on one core "
                    "where virtual devices share it)",
                    scaling["rows_per_s"] >= floor * mega["rows_per_s"],
                    f"{scaling['rows_per_s']:.0f} vs "
                    f"{mega['rows_per_s']:.0f} rows/s "
                    f"({cores} cores)",
                )
        else:
            claims.check(
                "mega-sweep subprocess leg completed",
                False,
                "benchmarks.mega_sweep subprocess failed; see stderr",
            )

    out = {
        "backend": backend,
        "candidates": n_candidates,
        "contexts": len(oracle.tables),
        "oracle": {
            "evals": oracle.evals,
            "wall_s": round(oracle_wall, 3),
        },
        **({"mega_sweep_jax": mega} if mega else {}),
        "sha_smoke_64": {
            "evals": sha.evals,
            "equivalent_evals": round(sha.equivalent_evals, 1),
            "oracle_evals": smoke_oracle.evals,
            "wall_s": round(sha_wall, 3),
            "worst_vs_oracle": round(sha_worst, 4),
        },
        "regret_median": {
            algo: round(agg["median"], 4)
            for algo, agg in report.per_algorithm.items()
        },
        "regret_mean": {
            algo: round(agg["mean"], 4)
            for algo, agg in report.per_algorithm.items()
        },
    }
    claims.check(
        "successive halving within 5% of oracle throughput at <= 1/4 "
        "of its candidate evaluations (smoke matrix, 64 candidates)",
        sha_worst >= 0.95
        and sha.equivalent_evals <= smoke_oracle.evals / 4.0,
        f"worst {sha_worst:.3f}, {sha.equivalent_evals:.0f} equivalent "
        f"evals vs oracle {smoke_oracle.evals}",
    )
    if grid_name == "full":
        med = out["regret_median"]
        claims.check(
            "adaptive heuristics approach the static oracle "
            "(MC/ProMC median regret >= 0.9 on the full matrix)",
            med.get("mc", 0) >= 0.9 and med.get("promc", 0) >= 0.9,
            f"median regret {med}",
        )
    return out


def run(claims) -> List[Dict]:
    global LAST_SNAPSHOT
    grid_name = os.environ.get("BENCH_EVAL_GRID", "full")
    grids = {
        "smoke": smoke_matrix,
        "default": default_matrix,
        "full": full_matrix,
    }
    scenarios = grids[grid_name]()
    n = len(scenarios)

    backends = {}
    results_of = {}
    for backend in ("event", "numpy", "jax"):
        backends[backend], results_of[backend] = _time_backend(
            scenarios, backend
        )

    # jax/numpy ratio vs grid size: where does the device loop cross over?
    by_size: Dict[str, float] = {}
    crossover = None
    for name in ("smoke", "default", "full"):
        sub = grids[name]()
        if len(sub) > n:  # never exceed the requested grid's cost
            break
        if len(sub) == n:  # the requested grid was measured above
            np_t = backends["numpy"]["wall_s"]
            jx_t = backends["jax"]["wall_s"]
        else:
            np_t = _time_backend(sub, "numpy")[0]["wall_s"]
            jx_t = _time_backend(sub, "jax")[0]["wall_s"]
        ratio = round(np_t / max(jx_t, 1e-9), 3)
        by_size[str(len(sub))] = ratio
        if crossover is None and ratio >= 1.0:
            crossover = len(sub)

    ratio_full = round(
        backends["numpy"]["wall_s"] / max(backends["jax"]["wall_s"], 1e-9), 3
    )
    if grid_name == "full":
        claims.check(
            "jax fabric backend beats NumPy scenarios/sec at full-matrix "
            "scale",
            ratio_full >= 1.0,
            f"{ratio_full:.2f}x at {n} scenarios (steady-state)",
        )
        claims.check(
            f"jax backend >= {_JAX_TARGET_RATIO:.0f}x NumPy (stretch target)",
            ratio_full >= _JAX_TARGET_RATIO,
            f"measured {ratio_full:.2f}x at {n}; ratio by grid size "
            f"{by_size}, crossover at {crossover} scenarios",
        )
        cold_tax = (
            backends["jax"]["wall_s_cold"] - backends["jax"]["wall_s"]
        )
        claims.check(
            f"jax cold-compile tax <= {_COLD_BUDGET_S:.0f}s on the full "
            "grid (canonical shape bucketing + persistent XLA cache)",
            cold_tax <= _COLD_BUDGET_S,
            f"cold {backends['jax']['wall_s_cold']:.1f}s - steady "
            f"{backends['jax']['wall_s']:.1f}s = {cold_tax:.1f}s "
            f"(persistent cache "
            f"{'on' if xla_cache.enabled() else 'off'})",
        )
        rps = backends["jax"].get("host_rounds_per_scenario", 1.0)
        replays = backends["jax"].get("post_row_replays_per_run", 1)
        claims.check(
            "zero-host-round fused loop: 0 host rounds/scenario "
            "(no parked-row replays, timeline rows included)",
            rps == 0 and replays == 0,
            f"{rps} host rounds/scenario, {replays} parked-row replays "
            "per run; "
            f"{backends['jax'].get('device_rounds_per_scenario', 0)} "
            "device while_loop entries/scenario",
        )
    else:
        # small grids favor eager NumPy by design (device-loop round-trip
        # overhead); record the measurement without gating on it
        claims.check(
            f"eval matrix bench runs on all backends (grid={grid_name})",
            True,
            f"jax/numpy {ratio_full:.2f}x at {n} scenarios",
        )

    tune_snapshot = _time_tuner(
        scenarios, grid_name, claims, results_of["numpy"]
    )

    # the multi-tenant fleet leg: coupled tenant_matrix throughput on
    # jax (rows/s + the sweep's own peak RSS via a fresh subprocess),
    # the coupled-vs-uncoupled overhead, and the contention report
    # (greedy per-tenant heuristics vs the contended static oracle) —
    # the full grid runs the full 36-group fleet, smaller grids the
    # 6-group smoke fleet
    fleet_matrix = "tenant" if grid_name == "full" else "tenant-smoke"
    fleet = _mega_subprocess(8, matrix=fleet_matrix)
    if fleet is not None:
        contention = fleet.get("contention", {})
        claims.check(
            "multi-tenant fleet: coupled sweep holds the RSS gate and "
            "greedy per-tenant tuning does not collapse under "
            "contention (median regret >= 0.75 vs the contended "
            "static oracle)",
            fleet["peak_rss_mb"] <= 1638.0
            and contention.get("regret_median", 0.0) >= 0.75,
            f"{fleet['evals']} tenants at {fleet['rows_per_s']:.0f} "
            f"rows/s, peak RSS {fleet['peak_rss_mb']:.0f} MB, "
            f"coupled overhead {fleet.get('coupled_overhead')}x, "
            f"median regret {contention.get('regret_median', 0):.3f} "
            f"({contention.get('groups', 0)} groups)",
        )
    else:
        claims.check(
            "multi-tenant fleet subprocess leg completed",
            False,
            "benchmarks.mega_sweep --matrix tenant subprocess failed",
        )

    LAST_SNAPSHOT = {
        "bench": "eval_matrix",
        "timestamp": round(time.time(), 1),
        "grid": {"name": grid_name, "scenarios": n},
        # execution provenance: jax/platform/devices + executor mode and
        # donation state the backends ran under
        "execution": _provenance(),
        # cold numbers only mean anything relative to this: with the
        # persistent cache armed (REPRO_XLA_CACHE) "cold" is a fresh
        # process reading compiled executables off disk; without it,
        # cold pays real XLA compiles
        "xla_cache": {
            "enabled": xla_cache.enabled(),
            "dir": xla_cache.cache_dir() if xla_cache.enabled() else None,
        },
        "backends": backends,
        "tune": tune_snapshot,
        "tenant_fleet": fleet,
        "jax_vs_numpy": {
            "steady_ratio": ratio_full,
            "target": _JAX_TARGET_RATIO,
            "ratio_by_grid_size": by_size,
            "crossover_scenarios": crossover,
        },
        # wall clocks are machine-relative: compare *ratios* across PRs,
        # and use the pure-Python event backend's scen/s as the
        # machine-speed canary before reading absolute deltas
        "notes": "same-run jax/numpy ratio is the cross-PR comparable; "
        "event scen/s calibrates machine drift between snapshots",
    }
    return [
        row(
            f"eval_matrix/{b}",
            m["wall_s"] * 1e6 / max(n, 1),
            f"{m['scen_per_s']} scen/s",
        )
        for b, m in backends.items()
    ]
