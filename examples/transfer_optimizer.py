"""The paper's contribution plus this repo's autotuner, end to end:

1. TUNE — run the static-parameter oracle over the smoke matrix (every
   paper testbed / size-class mix / scheduler appears), print each
   testbed's optimal (pipelining, parallelism, concurrency) and the
   regret table: how close SC / MC / ProMC get to the best static
   setting they never saw — the paper's headline claim, quantified.
2. SEARCH CHEAPER — successive halving and warm-started hill climbing
   find (nearly) the same winners at a fraction of the oracle's
   evaluations, persisting per-testbed winners to a JSON history store
   that seeds the next search.
3. REAL ENGINE (``--engine``) — the threaded engine moves actual files
   on local disk with the tuned schedulers (latency injection makes the
   pipelining effect visible).

    PYTHONPATH=src python examples/transfer_optimizer.py [--engine]
"""
import dataclasses
import hashlib
import os
import sys
import tempfile

from repro.core import prepare_chunks, testbeds, to_gbps
from repro.core.engine import TransferEngine, file_task
from repro.core.schedulers import make_scheduler
from repro.core.types import KB, MB, FileSpec
from repro.eval.runner import run_matrix
from repro.eval.scenarios import smoke_matrix
from repro.eval.tune import (
    HistoryStore,
    hill_climb,
    oracle_search,
    regret_report,
    successive_halving,
)


def tune_demo(backend: str = "numpy", n_candidates: int = 16):
    """Oracle + regret on the smoke matrix, then the budget searchers.

    Returns the oracle regret report (the system tests smoke this)."""
    scenarios = smoke_matrix()
    print(
        f"== tune: static-parameter oracle over the smoke matrix "
        f"({len(scenarios)} scenarios, {n_candidates}+ candidates each, "
        f"backend={backend}) =="
    )
    heuristics = run_matrix(scenarios, backend=backend)
    oracle = oracle_search(
        scenarios, backend=backend, n_candidates=n_candidates
    )
    report = regret_report(scenarios, heuristics, oracle)

    print("   per-testbed optima (first one per network):")
    seen = set()
    for entry in oracle.entries:
        net = entry.context[0]
        if net in seen:
            continue
        seen.add(net)
        pp, par, cc = entry.best_params
        print(
            f"   {net:<24s} pp={pp:<4d} p={par:<2d} cc={cc:<2d} "
            f"-> {to_gbps(entry.best_throughput):6.2f} Gbps"
        )
    print("   regret = heuristic / oracle throughput:")
    for line in report.format_table().splitlines():
        print(f"   {line}")

    with tempfile.TemporaryDirectory() as tmp:
        hist_path = os.path.join(tmp, "winners.json")
        history = HistoryStore(hist_path)
        sha = successive_halving(
            scenarios, backend=backend, n_candidates=n_candidates,
            history=history,
        )
        hill = hill_climb(
            scenarios, backend=backend, n_candidates=n_candidates,
            history=history,  # warm-started from the sha winners
        )
        history.save()
        oracle_best = {
            e.context: e.best_throughput for e in oracle.entries
        }
        for result in (sha, hill):
            worst = min(
                e.best_throughput / max(oracle_best[e.context], 1e-12)
                for e in result.entries
            )
            print(
                f"   {result.method:<6s} {result.evals:4d} evaluations "
                f"({result.equivalent_evals:6.1f} full-fidelity-equiv, "
                f"oracle spent {oracle.evals}); worst-case "
                f"{worst:.1%} of oracle throughput"
            )
        print(
            f"   {len(history)} per-testbed winners recorded (demo store is "
            "temporary; use `runner --tune ... --history PATH` to keep one)"
        )
    return report


def real_engine():
    print("== real engine: moving actual files on local disk ==")
    net = dataclasses.replace(testbeds.LAN, rtt=0.02)  # inject 20ms ctrl RTT
    with tempfile.TemporaryDirectory() as base:
        src, dst = os.path.join(base, "src"), os.path.join(base, "dst")
        os.makedirs(src), os.makedirs(dst)
        specs, tasks = [], {}
        sizes = [64 * KB] * 40 + [8 * MB] * 4
        for i, size in enumerate(sizes):
            name = f"f{i:03d}"
            path = os.path.join(src, name)
            with open(path, "wb") as f:
                f.write(os.urandom(size))
            spec = FileSpec(name=name, size=size, path=path)
            specs.append(spec)
            tasks[name] = file_task(spec, path, os.path.join(dst, name))

        for algo in ("sc", "mc", "promc"):
            for f in os.listdir(dst):
                os.unlink(os.path.join(dst, f))
            chunks = prepare_chunks(specs, net, 2, max_cc=4)
            sched = make_scheduler(algo, chunks, net, 4)
            eng = TransferEngine(net, tick_period=0.05, inject_latency=True)
            rep = eng.run(chunks, sched, tasks)
            print(
                f"   {algo:6s} {rep.total_bytes/1e6:6.1f} MB in "
                f"{rep.total_time:5.2f} s ({rep.throughput/1e6:6.1f} MB/s, "
                f"{rep.files_done} files)"
            )
        # verify integrity of the last run
        ok = all(
            hashlib.sha256(open(os.path.join(src, s.name), "rb").read()).digest()
            == hashlib.sha256(open(os.path.join(dst, s.name), "rb").read()).digest()
            for s in specs
        )
        print(f"   integrity: {'OK' if ok else 'CORRUPTED'}")


if __name__ == "__main__":
    tune_demo()
    if "--engine" in sys.argv[1:]:
        real_engine()
