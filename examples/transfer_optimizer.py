"""The paper's contribution, standalone: tune and schedule a mixed-file
transfer two ways —

1. SIMULATED on the paper's XSEDE testbed (reproduces the Sec. 4 behaviour:
   chunking, Algorithm-1 parameters, SC vs MC vs ProMC vs Globus/untuned);
2. REAL threaded engine moving actual files on local disk with the same
   schedulers (latency injection makes the pipelining effect visible).

    PYTHONPATH=src python examples/transfer_optimizer.py
"""
import dataclasses
import hashlib
import os
import tempfile

from repro.core import (
    prepare_chunks,
    run_transfer,
    testbeds,
    to_gbps,
)
from repro.core.engine import TransferEngine, file_task
from repro.core.schedulers import make_scheduler
from repro.core.types import KB, MB, FileSpec
from repro.data.filesets import mixed_dataset


def simulated():
    print("== simulated: mixed dataset on Stampede-Comet (10G WAN) ==")
    files = mixed_dataset(scale=0.03)
    total = sum(f.size for f in files) / 1e9
    print(f"   {len(files)} files, {total:.1f} GB")
    for algo in ("untuned", "globus", "sc", "mc", "promc"):
        r = run_transfer(files, testbeds.STAMPEDE_COMET, algo, max_cc=8)
        print(
            f"   {algo:8s} {to_gbps(r.throughput):6.2f} Gbps "
            f"({r.total_time:7.1f} s, {r.n_moves} channel moves)"
        )

    # show the tuned parameters per chunk (Algorithm 1)
    chunks = prepare_chunks(files, testbeds.STAMPEDE_COMET, 2, max_cc=8)
    for c in chunks:
        p = c.params
        print(
            f"   chunk {c.name:6s}: {len(c):5d} files avg "
            f"{c.avg_file_size/MB:7.1f} MB -> pipelining={p.pipelining} "
            f"parallelism={p.parallelism} concurrency={p.concurrency}"
        )


def real_engine():
    print("== real engine: moving actual files on local disk ==")
    net = dataclasses.replace(testbeds.LAN, rtt=0.02)  # inject 20ms ctrl RTT
    with tempfile.TemporaryDirectory() as base:
        src, dst = os.path.join(base, "src"), os.path.join(base, "dst")
        os.makedirs(src), os.makedirs(dst)
        specs, tasks = [], {}
        sizes = [64 * KB] * 40 + [8 * MB] * 4
        for i, size in enumerate(sizes):
            name = f"f{i:03d}"
            path = os.path.join(src, name)
            with open(path, "wb") as f:
                f.write(os.urandom(size))
            spec = FileSpec(name=name, size=size, path=path)
            specs.append(spec)
            tasks[name] = file_task(spec, path, os.path.join(dst, name))

        for algo in ("sc", "mc", "promc"):
            for f in os.listdir(dst):
                os.unlink(os.path.join(dst, f))
            chunks = prepare_chunks(specs, net, 2, max_cc=4)
            sched = make_scheduler(algo, chunks, net, 4)
            eng = TransferEngine(net, tick_period=0.05, inject_latency=True)
            rep = eng.run(chunks, sched, tasks)
            print(
                f"   {algo:6s} {rep.total_bytes/1e6:6.1f} MB in "
                f"{rep.total_time:5.2f} s ({rep.throughput/1e6:6.1f} MB/s, "
                f"{rep.files_done} files)"
            )
        # verify integrity of the last run
        ok = all(
            hashlib.sha256(open(os.path.join(src, s.name), "rb").read()).digest()
            == hashlib.sha256(open(os.path.join(dst, s.name), "rb").read()).digest()
            for s in specs
        )
        print(f"   integrity: {'OK' if ok else 'CORRUPTED'}")


if __name__ == "__main__":
    simulated()
    real_engine()
