"""Batched serving example: prefill a batch of prompts, then decode
autoregressively with a shared jitted decode step and per-request lengths —
the serving pattern the decode_32k / long_500k dry-run cells lower at scale.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-1b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import frontend_stubs
from repro.models.config import reduce_for_smoke
from repro.models.model import build_model
from repro.train.serve_step import make_decode_step, make_prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.RandomState(0)
    prompts = rng.randint(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len)
    ).astype(np.int32)
    batch = {"tokens": jnp.asarray(prompts)}
    batch.update(
        {k: jnp.asarray(v) for k, v in frontend_stubs(cfg, args.batch).items()}
    )
    prefix = cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0
    max_len = prefix + args.prompt_len + args.new_tokens

    prefill = jax.jit(make_prefill(model))
    decode = jax.jit(make_decode_step(model, temperature=args.temperature))

    cache = model.init_cache(args.batch, max_len)
    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    out = [tok]
    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        key, sub = jax.random.split(key)
        pos = jnp.int32(prefix + args.prompt_len + i)
        tok, cache = decode(params, tok, cache, pos, sub)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    generated = np.stack([np.asarray(t) for t in out], axis=1)
    total_new = args.batch * args.new_tokens
    print(
        f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
        f"(+{prefix} prefix) new={args.new_tokens}"
    )
    print(
        f"prefill {t_prefill*1e3:.0f} ms; decode {t_decode*1e3:.0f} ms "
        f"({total_new/max(t_decode,1e-9):.1f} tok/s incl. jit warmup)"
    )
    for b in range(args.batch):
        print(f"req[{b}]: {prompts[b,:6].tolist()}... -> "
              f"{generated[b,:8].tolist()}...")


if __name__ == "__main__":
    main()
