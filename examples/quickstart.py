"""Quickstart: build a tiny assigned-architecture model, train a few steps on
synthetic data, checkpoint it, and generate a few tokens.

    PYTHONPATH=src python examples/quickstart.py [--arch llama3.2-3b]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models.config import reduce_for_smoke
from repro.models.model import build_model, count_params
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, train
from repro.train.serve_step import generate
from repro.train.train_step import StepConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    model = build_model(cfg)
    print(f"arch={cfg.name} params={count_params(model):,}")

    data = SyntheticLM(cfg, DataConfig(global_batch=8, seq_len=64))
    step_cfg = StepConfig(
        optimizer=AdamWConfig(lr=3e-3, warmup_steps=5,
                              total_steps=args.steps, weight_decay=0.0)
    )
    with tempfile.TemporaryDirectory() as d:
        result = train(
            model, step_cfg, data.batches(),
            LoopConfig(total_steps=args.steps, ckpt_every=10, ckpt_dir=d,
                       log_every=5),
            on_metrics=lambda s, m: print(
                f"step {s:4d} loss {m['loss']:.3f} ({m['time_s']*1e3:.0f} ms)"
            ),
        )
        print(f"checkpoints in {d}: latest step {ckpt.latest_step(d)}")

    params = result["state"]["params"]
    prompt = jnp.asarray([[5, 17, 11, 2]], jnp.int32)
    toks = generate(model, params, prompt, max_new_tokens=8, max_len=32)
    print("generated token ids:", toks[0].tolist())


if __name__ == "__main__":
    main()
