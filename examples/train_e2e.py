"""End-to-end training driver: a ~100M-parameter llama-family model trained
for a few hundred steps on the synthetic corpus, with async checkpointing,
crash-resume, and metrics logging — the full production loop at laptop scale.

Full run (~100M params; slow on 1 CPU core):
    PYTHONPATH=src python examples/train_e2e.py --preset 100m --steps 300
CI-scale run (~25M params, finishes in minutes):
    PYTHONPATH=src python examples/train_e2e.py --preset 25m --steps 200
"""
import argparse
import dataclasses
import json
import os
import time

from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models.model import build_model, count_params
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, train
from repro.train.train_step import StepConfig

PRESETS = {
    # name: (layers, d_model, heads, kv, d_ff, vocab, seq, batch)
    "100m": (12, 768, 12, 4, 2048, 32768, 512, 8),
    "25m": (8, 384, 6, 2, 1024, 16384, 256, 8),
    "5m": (4, 192, 4, 2, 512, 4096, 128, 8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="25m", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--log", default="/tmp/repro_e2e_log.json")
    args = ap.parse_args()

    L, d, h, kv, ff, v, seq, batch = PRESETS[args.preset]
    cfg = dataclasses.replace(
        get_config("llama3.2-3b"),
        name=f"llama-{args.preset}",
        num_layers=L, d_model=d, num_heads=h, num_kv_heads=kv,
        head_dim=d // h, d_ff=ff, vocab_size=v,
    )
    model = build_model(cfg, remat="none")
    n = count_params(model)
    print(f"model {cfg.name}: {n/1e6:.1f}M params, seq={seq}, batch={batch}")

    data = SyntheticLM(cfg, DataConfig(global_batch=batch, seq_len=seq))
    step_cfg = StepConfig(
        optimizer=AdamWConfig(
            lr=6e-4, warmup_steps=40, total_steps=args.steps,
            weight_decay=0.05,
        )
    )
    os.makedirs(args.ckpt_dir, exist_ok=True)
    history = []

    def log(step, m):
        history.append(m)
        tok_s = batch * seq / m["time_s"]
        print(
            f"step {step:5d}  loss {m['loss']:.4f}  lr {m['lr']:.2e}  "
            f"gnorm {m['grad_norm']:.2f}  {tok_s/1e3:.1f}k tok/s"
        )

    t0 = time.time()
    result = train(
        model, step_cfg, data.batches(),
        LoopConfig(
            total_steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt_dir,
            async_ckpt=True, log_every=10,
        ),
        on_metrics=log,
    )
    wall = time.time() - t0
    first = history[0]["loss"] if history else float("nan")
    last = history[-1]["loss"] if history else float("nan")
    print(
        f"done: {args.steps} steps in {wall/60:.1f} min; "
        f"loss {first:.3f} -> {last:.3f}"
    )
    with open(args.log, "w") as f:
        json.dump({"preset": args.preset, "params": n, "history": history}, f)
    print(f"metrics -> {args.log}; checkpoints -> {args.ckpt_dir}")


if __name__ == "__main__":
    main()
